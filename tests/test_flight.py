"""In-flight cohort telemetry (ISSUE 10): flight taps, divergence
sentinels, live surfaces, and the zero-overhead contract.

The load-bearing invariants under test:

* **Zero overhead when off** — with no flight recorder installed the
  runtime builds the exact untapped computation: no ``io_callback``
  appears in the block jaxpr, and no ``meta/flight`` directory is born.
* **Byte identity when on** — a tapped blocked run's store is
  byte-identical to the untapped blocked run; everything the flight
  recorder writes lands under ``meta/``.
* **Divergence routes to quarantine** — an injected NaN carry trips the
  ``nan`` sentinel between blocks, aborts the cohort non-retryably in
  well under the full-run wall, leaves a structured ``diverged``
  quarantine record, and a healing re-run reproduces the uninterrupted
  store exactly.
* **Live surfaces agree** — the daemon's ``/live``, the
  ``rounds_in_flight`` gauge, and ``python -m repro.obs watch`` all read
  the same recorder state.
"""

import json
import os
import signal
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import pytest

from repro.obs import flight, trace
from repro.obs import __main__ as obs_main
from repro.runtime import faults
from repro.serve import api as api_lib
from repro.serve import client as client_lib
from repro.serve import session as session_lib
from repro.sweep import SweepSpec, SweepStore, cells, cohorts, run_spec
from repro.sweep.grid import prepare_cohort_phases

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(autouse=True)
def _flight_clean():
    """Flight/trace recorders and fault plans are process-global; never
    leak them between tests."""
    yield
    flight.uninstall()
    trace.uninstall()
    faults.install(None)


U, K_BAR = 4, 6

SPEC = SweepSpec(axes={"seed": (0, 1)},
                 base={"task": "linreg", "U": U, "k_bar": K_BAR,
                       "rounds": 6})
SPEC_DIV = SweepSpec(axes={"seed": (0, 1)},
                     base={"task": "linreg", "U": U, "k_bar": K_BAR,
                           "rounds": 20})

_ENV = dict(os.environ, JAX_PLATFORMS="cpu",
            PYTHONPATH=os.pathsep.join(
                [os.path.join(os.path.dirname(__file__), "..", "src")]
                + sys.path))


def _store_files(root):
    return {f: open(os.path.join(root, f), "rb").read()
            for f in sorted(os.listdir(root)) if f.endswith(".json")}


def _one_cohort(spec):
    (cohort,) = cohorts(cells(spec))
    return cohort


# ----------------------------------------------------------- the grammar

def test_parse_predicates_grammar():
    # default (None / empty) arms NaN detection
    assert flight.parse_predicates(None) == flight.parse_predicates("")
    (p,) = flight.parse_predicates(None)
    assert (p.kind, p.streak) == ("nan", 1)

    preds = flight.parse_predicates("nan, gap_bound:10:3,snr_below:-5:2")
    assert [p.kind for p in preds] == ["nan", "gap_bound", "snr_below"]
    assert preds[1].threshold == 10.0 and preds[1].streak == 3
    assert preds[2].threshold == -5.0 and preds[2].streak == 2
    # text round-trips through the parser
    assert flight.parse_predicates(
        ",".join(p.text for p in preds)) == preds

    for bad in ("nan:1", "gap_bound:10", "gap_bound:10:0",
                "snr_below", "warp_core_breach:1:1"):
        with pytest.raises(ValueError):
            flight.parse_predicates(bad)


def _rec_for(loss, *, a=0.9, b=0.0, snr_db=20.0, finite=True):
    return {"finite": finite, "loss": [loss], "a_block": [a],
            "b_block": [b], "snr_db": [snr_db]}


def test_sentinel_gap_bound_streak_and_reset():
    s = flight.DivergenceSentinel(
        flight.parse_predicates("gap_bound:2:2"))
    # seed block establishes the bound; never compares
    assert s.observe(_rec_for(1.0)) is None
    # bound is now 0.9; loss within margin -> no trip, streak stays 0
    assert s.observe(_rec_for(1.0)) is None
    # two consecutive blocks over margin trip on the SECOND
    assert s.observe(_rec_for(50.0)) is None
    reason, pred = s.observe(_rec_for(50.0))
    assert "Lemma-1" in reason and pred == "gap_bound:2:2"

    # a healthy block in between resets the streak
    s2 = flight.DivergenceSentinel(
        flight.parse_predicates("gap_bound:2:2"))
    s2.observe(_rec_for(1.0))               # seed
    assert s2.observe(_rec_for(50.0)) is None    # streak 1
    assert s2.observe(_rec_for(0.1)) is None     # reset
    assert s2.observe(_rec_for(50.0)) is None    # streak 1 again


def test_sentinel_snr_and_nan():
    s = flight.DivergenceSentinel(
        flight.parse_predicates("snr_below:-10:2"))
    s.observe(_rec_for(1.0, snr_db=0.0))
    assert s.observe(_rec_for(1.0, snr_db=-20.0)) is None
    reason, pred = s.observe(_rec_for(1.0, snr_db=-20.0))
    assert "SNR" in reason and pred == "snr_below:-10:2"

    n = flight.DivergenceSentinel(flight.parse_predicates("nan"))
    assert n.observe(_rec_for(1.0)) is None
    reason, pred = n.observe(_rec_for(1.0, finite=False))
    assert "non-finite" in reason and pred == "nan"


# --------------------------------------------- zero-overhead contract

def test_block_jaxpr_has_no_io_callback_when_off():
    """Satellite: the tap is structurally absent from the computation
    the untapped runtime compiles — not merely disabled."""
    cohort = _one_cohort(SPEC)
    phases = prepare_cohort_phases(cohort)
    state = jax.jit(jax.vmap(phases.init_one))(phases.batch)
    n = 2
    eval_every = int(cohort.static["eval_every"])
    offs = tuple(j for j in range(n) if j % eval_every == 0)
    base = jax.vmap(phases.block_one(n, offs))

    plain = str(jax.make_jaxpr(base)(state, phases.batch))
    assert "io_callback" not in plain

    tapped = flight.wrap_block(base)
    wrapped = str(jax.make_jaxpr(tapped)(
        state, phases.batch, jnp.int32(0), jnp.int32(n)))
    assert "io_callback" in wrapped


def test_module_noop_when_uninstalled(tmp_path, monkeypatch):
    assert not flight.enabled() and flight.installed() is None
    flight.flush()                        # no-op, no raise
    monkeypatch.delenv(flight.ENV_VAR, raising=False)
    assert flight.install_from_env() is None
    assert flight.load_statuses(str(tmp_path)) == []

    # an untapped blocked run never creates the flight directory
    store = tmp_path / "store"
    run_spec(SPEC, store=SweepStore(str(store)), checkpoint_every=2)
    assert not os.path.exists(flight.flight_dir_for(str(store)))


def test_install_is_idempotent_per_dir(tmp_path):
    a = flight.install(str(tmp_path / "f"))
    assert flight.install(str(tmp_path / "f")) is a
    b = flight.install(str(tmp_path / "f"), predicates="nan,snr_below:0:1")
    assert b is not a and flight.installed() is b
    monkey_dir = str(tmp_path / "g")
    os.environ[flight.ENV_VAR] = monkey_dir
    try:
        c = flight.install_from_env()
        assert c is not None and c.dir == monkey_dir
    finally:
        del os.environ[flight.ENV_VAR]


# ------------------------------------------------- byte identity + live

def test_tapped_run_byte_identical_and_watch(tmp_path, capsys):
    ref = tmp_path / "ref"
    run_spec(SPEC, store=SweepStore(str(ref)), checkpoint_every=2)

    tapped = tmp_path / "tap"
    flight.install(flight.flight_dir_for(str(tapped)))
    results = run_spec(SPEC, store=SweepStore(str(tapped)),
                       checkpoint_every=2)
    assert all(r is not None for r in results)

    # the cardinal invariant: taps never change result bytes, and all
    # flight output lives under meta/
    assert _store_files(str(tapped)) == _store_files(str(ref))
    fdir = flight.flight_dir_for(str(tapped))
    assert os.path.isdir(fdir) and fdir.startswith(
        os.path.join(str(tapped), "meta"))

    (status,) = flight.load_statuses(str(tapped))
    assert status["status"] == "done"
    assert status["r_done"] == status["rounds"] == 6
    assert status["cells"] == 2
    tail = status["tail"]
    assert tail["finite"] is True
    assert len(tail["snr_db"]) == 2 and len(tail["a_last"]) == 2
    assert tail["loss_key"] == "mse" and len(tail["loss"]) == 2

    # the in-process snapshot agrees with the on-disk status
    (snap,) = flight.installed().snapshot()
    assert snap["sig"] == status["sig"] and snap["r_done"] == 6
    assert flight.installed().rounds_remaining() == 0

    # `obs watch --once` renders the same store view
    assert obs_main.main(["watch", str(tapped), "--once"]) == 0
    out = capsys.readouterr().out
    assert "done" in out and "6/6" in out
    assert status["sig"][:12] in out


# --------------------------------------------- divergence -> quarantine

def test_nan_sentinel_aborts_early_then_heals(tmp_path):
    rounds = int(SPEC_DIV.base["rounds"])
    ref = tmp_path / "ref"
    run_spec(SPEC_DIV, store=SweepStore(str(ref)), checkpoint_every=2)

    store = tmp_path / "div"
    flight.install(flight.flight_dir_for(str(store)),
                   predicates="nan")
    # poison the carry after block 1 (round 2); the block-2 tap sees it
    faults.install(faults.parse("nan_at_block:1"))
    results = run_spec(SPEC_DIV, store=SweepStore(str(store)),
                       checkpoint_every=2, quarantine=True,
                       max_retries=2)
    assert all(r is None for r in results)

    failed_dir = os.path.join(str(store), "failed")
    (fn,) = [f for f in os.listdir(failed_dir) if f.endswith(".json")]
    with open(os.path.join(failed_dir, fn)) as f:
        doc = json.load(f)
    assert doc["kind"] == "diverged"
    assert doc["error"]["type"] == "CohortDiverged"
    div = doc["diverged"]
    assert div["predicate"] == "nan" and div["cells"] == 2
    assert "non-finite" in div["reason"]
    # aborted in well under the full run: detection lands one block
    # after the poison, at <= 25% of the cohort's rounds
    assert div["round"] <= 0.25 * rounds
    # non-retryable: the sentinel fired once, not once per retry
    assert doc["attempts"] == 1

    (status,) = flight.load_statuses(str(store))
    assert status["status"] == "diverged"
    assert status["diverged"]["round"] == div["round"]

    # the poisoned checkpoint must not survive to seed a resume
    runtime_dir = os.path.join(str(store), ".runtime", "ckpt")
    assert not os.path.isdir(runtime_dir) or not os.listdir(runtime_dir)

    # heal: clear the fault, re-run the same grid (taps still on) —
    # byte-identical to the uninterrupted store, quarantine cleared
    faults.install(faults.parse(""))
    results2 = run_spec(SPEC_DIV, store=SweepStore(str(store)),
                        checkpoint_every=2, quarantine=True)
    assert all(r is not None for r in results2)
    assert _store_files(str(store)) == _store_files(str(ref))
    assert [f for f in os.listdir(failed_dir)
            if f.endswith(".json")] == []


def test_cli_flight_flag_validation():
    from repro.sweep import cli
    with pytest.raises(SystemExit):       # --flight needs a store
        cli.main(["--flight", "--axis", "seed=0:2"])
    with pytest.raises(SystemExit):       # bad sentinel grammar
        cli.main(["--store", "s", "--sentinel", "bogus:1",
                  "--axis", "seed=0:2"])
    with pytest.raises(SystemExit):       # submit is remote-only
        cli.main(["--submit", "x:1", "--flight",
                  "--axis", "seed=0:2"])


# -------------------------------------------------- trace lane merging

def _write_trace(path, records):
    with open(path, "w") as f:
        for r in records:
            f.write(json.dumps(r) + "\n")


def test_export_chrome_merges_lanes_and_flows(tmp_path):
    """Two processes touching one cohort: the merged export grows named
    per-pid/host lanes and a claim-steal flow arrow between them."""
    da = tmp_path / "a"
    rec = trace.TraceRecorder(str(da), flush_every=1)
    rec.event("claim.acquire", cat="claims", sig="SIG1")
    rec.close()

    db = tmp_path / "b"
    os.makedirs(str(db))
    thief = os.getpid() + 1
    _write_trace(str(db / f"trace-{thief}-0.jsonl"), [
        {"name": "clock_sync", "ph": "M", "pid": thief, "tid": 0,
         "ts": int(time.time() * 1e6),
         "args": {"host": "otherhost", "epoch_us": int(time.time() * 1e6),
                  "mono_us": int(time.monotonic() * 1e6)}},
        {"name": "claim.steal", "cat": "claims", "ph": "i", "s": "t",
         "ts": int(time.time() * 1e6) + 1000, "pid": thief, "tid": 0,
         "args": {"sig": "SIG1"}},
    ])

    doc = trace.export_chrome([str(da), str(db)])
    evs = doc["traceEvents"]
    assert doc["otherData"]["processes"] == 2
    assert "otherhost" in doc["otherData"]["hosts"]

    lanes = {e["args"]["name"] for e in evs
             if e.get("ph") == "M" and e["name"] == "process_name"}
    assert any(f"pid {os.getpid()}" in l for l in lanes)
    assert f"otherhost pid {thief}" in lanes

    flows = [e for e in evs if e.get("name") == "claim-steal"]
    assert {e["ph"] for e in flows} == {"s", "f"}
    src = next(e for e in flows if e["ph"] == "s")
    dst = next(e for e in flows if e["ph"] == "f")
    assert src["id"] == dst["id"] and src["pid"] == os.getpid()
    assert dst["pid"] == thief and dst["args"]["sig"] == "SIG1"

    # timestamps rebased to the earliest event
    assert min(e["ts"] for e in evs if "ts" in e) == 0


def test_export_chrome_corrects_same_host_skew(tmp_path):
    """Two same-host processes whose wall clocks disagree by 0.5s: the
    monotonic clock_sync offsets cancel the skew, so two events that
    happened at the same monotonic instant land on the same ts."""
    d = tmp_path / "t"
    os.makedirs(str(d))
    recs = []
    for pid, epoch0, ev_ts in ((111, 1_000_000, 2_000_000),
                               (222, 1_500_000, 2_500_000)):
        _write_trace(str(d / f"trace-{pid}-0.jsonl"), [
            {"name": "clock_sync", "ph": "M", "pid": pid, "tid": 0,
             "ts": epoch0, "args": {"host": "samehost",
                                    "epoch_us": epoch0,
                                    "mono_us": 1_000_000}},
            {"name": "tick", "cat": "t", "ph": "i", "s": "t",
             "ts": ev_ts, "pid": pid, "tid": 0, "args": {}},
        ])
        recs.append((pid, ev_ts))

    doc = trace.export_chrome(str(d))
    ticks = {e["pid"]: e["ts"] for e in doc["traceEvents"]
             if e.get("name") == "tick"}
    # pid 222's wall clock runs 500ms ahead; post-correction both ticks
    # coincide (and rebase to 0)
    assert ticks[111] == ticks[222] == 0


# ------------------------------------------------- the daemon surfaces

def _wait_done(svc, rid, timeout=180.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        snap = svc.request_snapshot(rid)
        if snap["state"] == "done":
            return snap
        time.sleep(0.05)
    raise AssertionError(f"request never settled: "
                         f"{svc.request_snapshot(rid)}")


def test_service_live_endpoint_and_gauges(tmp_path):
    store = str(tmp_path / "store")
    svc = session_lib.SweepService(store, jobs=1, poll_s=0.1,
                                   flight=True, checkpoint_every=2)
    server = api_lib.make_server(svc, "127.0.0.1", 0)
    threading.Thread(target=server.serve_forever, daemon=True).start()
    host, port = server.server_address
    base = f"http://{host}:{port}"
    try:
        spec = SweepSpec(axes={"seed": (0, 1)},
                         base={"task": "linreg", "U": U, "k_bar": K_BAR,
                               "rounds": 5})
        snap = svc.submit(spec, client="t")
        _wait_done(svc, snap["id"])

        # /live: well-formed whether or not cohorts are still in flight
        with urllib.request.urlopen(f"{base}/live", timeout=30) as r:
            doc = json.loads(r.read())
        assert set(doc) >= {"ts", "rounds_in_flight", "cohorts"}
        assert doc["rounds_in_flight"] == 0          # flight finished
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{base}/sweep/nope/live", timeout=30)
        assert ei.value.code == 404
        with pytest.raises(KeyError):
            svc.live(rid="nope")

        # the recorder fed both metric surfaces: the in-flight gauge and
        # the realized rounds/sec histogram (one observation per tap)
        text = svc.registry.render_prometheus()
        assert "repro_serve_rounds_in_flight 0" in text
        assert "repro_serve_cohort_rounds_per_s_count" in text
        hist = svc.registry.snapshot()["cohort_rounds_per_s"]
        assert hist["count"] >= 2                    # 3 blocks tapped

        # the same run's status file serves obs watch / heal readers
        (status,) = flight.load_statuses(store)
        assert status["status"] == "done" and status["r_done"] == 5
    finally:
        server.shutdown()
        server.server_close()
        svc.close()


def test_daemon_sigterm_flushes_trace(tmp_path):
    """Satellite: SIGTERM (systemd/docker stop, CI kill) must flush the
    buffered trace tail instead of dying with it in memory."""
    d = str(tmp_path / "store")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serve", "--store", d,
         "--listen", "127.0.0.1:0", "--jobs", "1", "--trace", "-q"],
        env=_ENV, stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
        text=True)
    try:
        line = proc.stdout.readline()
        assert line.startswith("listening on "), line
        addr = line.split()[-1]
        spec = SweepSpec(axes={"seed": (0, 1)},
                         base={"task": "linreg", "U": U, "k_bar": K_BAR,
                               "rounds": 3})
        client_lib.submit_and_wait(addr, spec, poll_s=0.2, timeout_s=180)
        # the tail of the request's spans/events sits in the recorder
        # buffer; a graceful stop must persist it
        proc.send_signal(signal.SIGTERM)
        rc = proc.wait(timeout=60)
        assert rc == 0, f"SIGTERM should exit cleanly, got {rc}"
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()

    evs = trace.load_events(trace.trace_dir_for(d))
    names = {e["name"] for e in evs}
    assert "session.classify" in names      # the submit made it to disk
    assert "session.submit" in names
