"""Observability layer: tracing, metrics, logs, report.

The load-bearing guarantee: telemetry is a pure OBSERVER.  Installing
the trace recorder must never change result bytes — everything it
writes lands under ``<store>/meta/``, which byte-identity comparisons
exclude.  The registry is the single source for every metrics surface,
so its JSON snapshot and its Prometheus text must always agree.
"""

import io
import json
import os
import threading

import jax
import pytest

from repro.obs import logs, metrics, report, trace
from repro.obs.trace import TraceRecorder
from repro.sweep import SweepSpec, SweepStore, run_spec

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(autouse=True)
def _obs_clean():
    """Trace install and log mode are process-global: never leak them
    into other tests (or between tests here)."""
    yield
    trace.uninstall()
    logs.configure(json_mode=False, stream=None)


U, K_BAR, ROUNDS = 4, 6, 3


# ----------------------------------------------------------------- recorder

def test_recorder_thread_safety(tmp_path):
    rec = TraceRecorder(str(tmp_path), flush_every=8)
    threads, per = 8, 50

    def work(tid):
        for i in range(per):
            with rec.span("work", cat="test", tid=tid, i=i):
                pass
            rec.event("tick", cat="test", tid=tid, i=i)

    ts = [threading.Thread(target=work, args=(k,)) for k in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    rec.close()

    evs = trace.load_events(str(tmp_path))
    assert len(evs) == threads * per * 2        # every record survived
    assert all(e["name"] in ("work", "tick") for e in evs)
    # spans carry integer microsecond ts + dur; events are instants
    for e in evs:
        assert isinstance(e["ts"], int)
        if e["ph"] == "X":
            assert isinstance(e["dur"], int) and e["dur"] >= 0
        else:
            assert e["ph"] == "i" and e["s"] == "t"


def test_recorder_span_records_error(tmp_path):
    rec = TraceRecorder(str(tmp_path), flush_every=1)
    with pytest.raises(ValueError):
        with rec.span("boom", cat="test"):
            raise ValueError("no")
    rec.close()
    (ev,) = trace.load_events(str(tmp_path))
    assert ev["args"]["error"] == "ValueError"


def test_recorder_unique_files_per_life(tmp_path):
    a = TraceRecorder(str(tmp_path))
    b = TraceRecorder(str(tmp_path))        # same pid, same dir
    assert a.path != b.path
    a.close(), b.close()


def test_module_api_noop_when_uninstalled(tmp_path):
    trace.uninstall()
    assert not trace.enabled()
    trace.event("ignored")                  # must not raise
    with trace.span("ignored") as args:
        args["k"] = 1                       # mutable dict even when off
    trace.flush()
    assert os.listdir(str(tmp_path)) == []


def test_install_from_env(tmp_path, monkeypatch):
    d = str(tmp_path / "t")
    monkeypatch.setenv(trace.ENV_VAR, d)
    assert trace.install_from_env() is not None
    assert trace.enabled()
    trace.event("hello", cat="test")
    trace.uninstall()
    assert [e["name"] for e in trace.load_events(d)] == ["hello"]


# ------------------------------------------------------------ chrome export

def test_export_chrome_schema(tmp_path):
    rec = TraceRecorder(str(tmp_path), flush_every=1)
    with rec.span("outer", cat="test"):
        rec.event("mark", cat="test", k=1)
    rec.close()

    doc = trace.export_chrome(str(tmp_path))
    assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert len(evs) == 2
    # timestamps re-based to the earliest event
    assert min(e["ts"] for e in evs) == 0
    for e in evs:
        assert {"name", "cat", "ph", "ts", "pid", "tid"} <= set(e)
        assert e["ph"] in ("X", "i")
    # the whole document round-trips as JSON (Perfetto loads it)
    json.loads(json.dumps(doc))


# ----------------------------------------------------------------- registry

def _parse_prometheus(text):
    """{series-with-labels: float} from exposition text."""
    out = {}
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        name, val = line.rsplit(" ", 1)
        out[name] = float(val.replace("+Inf", "inf"))
    return out


def test_registry_snapshot_matches_prometheus():
    r = metrics.Registry(namespace="repro_test")
    r.counter("jobs_done").inc(3)
    r.gauge("depth").set(2.5)
    r.gauge("temp", fn=lambda: 7.0)
    g = r.gauge("queued_s")
    g.set_labeled(1.5, client="a")
    g.set_labeled(0.25, client="b")
    h = r.histogram("wall_s", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(99.0)

    snap = r.snapshot()
    prom = _parse_prometheus(r.render_prometheus())

    assert snap["jobs_done"] == 3
    assert prom["repro_test_jobs_done"] == 3.0
    assert snap["depth"] == 2.5 == prom["repro_test_depth"]
    assert snap["temp"] == 7 == prom["repro_test_temp"]
    assert snap["queued_s"]["labeled"]['{client="a"}'] == 1.5
    assert prom['repro_test_queued_s{client="a"}'] == 1.5
    assert prom['repro_test_queued_s{client="b"}'] == 0.25
    assert snap["wall_s"]["count"] == 3 == prom["repro_test_wall_s_count"]
    assert snap["wall_s"]["sum"] == pytest.approx(99.55)
    # cumulative buckets agree between surfaces
    assert snap["wall_s"]["buckets"] == {"0.1": 1, "1": 2, "+Inf": 3}
    assert prom['repro_test_wall_s_bucket{le="0.1"}'] == 1.0
    assert prom['repro_test_wall_s_bucket{le="1"}'] == 2.0
    assert prom['repro_test_wall_s_bucket{le="+Inf"}'] == 3.0


def test_registry_get_or_create_and_kind_mismatch():
    r = metrics.Registry()
    c = r.counter("n")
    assert r.counter("n") is c
    with pytest.raises(TypeError):
        r.gauge("n")
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_callback_failure_reads_zero():
    r = metrics.Registry()
    g = r.gauge("bad", fn=lambda: 1 / 0)
    assert g.get() == 0.0
    assert "bad 0" in r.render_prometheus()


def test_registry_dump(tmp_path):
    r = metrics.Registry(namespace="repro_sweep")
    r.counter("cells_computed").inc(4)
    p = str(tmp_path / "m.json")
    r.dump(p)
    doc = json.load(open(p))
    assert doc["namespace"] == "repro_sweep"
    assert doc["metrics"]["cells_computed"] == 4


# --------------------------------------------------------------------- logs

def test_logs_plain_mode_is_byte_stable():
    buf = io.StringIO()
    logs.configure(json_mode=False, stream=buf)
    logs.emit("serve", "started", plain="store=s jobs=2", extra=1)
    logs.emit("serve", "hidden", plain=None)     # JSON-only record
    logs.raw("listening on 127.0.0.1:8477")
    assert buf.getvalue() == ("# serve: store=s jobs=2\n"
                              "listening on 127.0.0.1:8477\n")


def test_logs_json_mode_one_object_per_line():
    buf = io.StringIO()
    logs.configure(json_mode=True, stream=buf)
    logs.emit("serve", "started", plain="store=s", jobs=2)
    logs.emit("serve", "hidden", plain=None, level="debug")
    logs.raw("listening on 127.0.0.1:8477")
    recs = [json.loads(line) for line in buf.getvalue().splitlines()]
    assert len(recs) == 3
    for rec in recs:
        assert {"ts", "level", "component", "event"} <= set(rec)
    assert recs[0]["event"] == "started" and recs[0]["jobs"] == 2
    assert recs[1]["level"] == "debug"
    assert recs[2]["event"] == "raw"
    assert "listening on" in recs[2]["message"]


# ------------------------------------------------- tracing is a pure observer

def _store_files(root):
    return {f: open(os.path.join(root, f), "rb").read()
            for f in sorted(os.listdir(root)) if f.endswith(".json")}


def test_traced_sweep_is_byte_identical(tmp_path):
    spec = SweepSpec(axes={"seed": (0, 1)},
                     base={"task": "linreg", "U": U, "k_bar": K_BAR,
                           "rounds": ROUNDS})

    plain_root = str(tmp_path / "plain")
    run_spec(spec, store=SweepStore(plain_root), verbose=False)

    traced_root = str(tmp_path / "traced")
    trace.install(trace.trace_dir_for(traced_root))
    try:
        run_spec(spec, store=SweepStore(traced_root), verbose=False)
    finally:
        trace.uninstall()

    # telemetry landed, and ONLY under meta/
    evs = trace.load_events(trace.trace_dir_for(traced_root))
    assert {e["name"] for e in evs} >= {"sweep.submit", "store.put",
                                        "cohort.run"}
    assert _store_files(plain_root) == _store_files(traced_root)


def test_report_renders_and_export(tmp_path):
    root = str(tmp_path / "store")
    spec = SweepSpec(axes={"seed": (0, 1)},
                     base={"task": "linreg", "U": U, "k_bar": K_BAR,
                           "rounds": ROUNDS})
    trace.install(trace.trace_dir_for(root))
    try:
        run_spec(spec, store=SweepStore(root), verbose=False)
    finally:
        trace.uninstall()

    text = report.render(root)
    assert "per-cell OTA telemetry" in text
    assert "seed=0" in text and "seed=1" in text
    assert "trace (" in text
    rows = report.ota_rows(report.load_cells(root))
    assert len(rows) == 2
    for row in rows:
        # realized contraction factor respects the Lemma-1 floor 1-mu/L
        assert row["a_mean"] >= row["a_floor"] - 1e-6
        assert row["gap_bound"] > 0

    doc = trace.export_chrome(trace.trace_dir_for(root))
    assert any(e["name"] == "cohort.run" for e in doc["traceEvents"])


def test_costbook_rows_flag_mispredict(tmp_path):
    root = str(tmp_path / "store")
    SweepStore(root)                        # create the root
    from repro.sweep.store import CostBook
    costs = CostBook(root)
    costs.record("k" * 16, wall_s=10.0, cells=2, predicted_s=1.0)
    costs.record("m" * 16, wall_s=1.0, cells=1, predicted_s=0.9)
    rows = report.costbook_rows(root)
    by_key = {r["key"]: r for r in rows}
    assert by_key["k" * 16]["mispredicted"] is True
    assert by_key["m" * 16]["mispredicted"] is False
