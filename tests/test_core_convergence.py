"""Unit tests for the closed-form convergence terms (Theorems 1-3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import convergence as cv
from repro.core.convergence import LearningConstants

jax.config.update("jax_enable_x64", True)


def _setup(U=5, D=7, seed=0):
    rng = np.random.default_rng(seed)
    k_i = jnp.asarray(rng.integers(20, 40, size=U), jnp.float64)
    beta = jnp.asarray(rng.integers(0, 2, size=(U, D)), jnp.float64)
    # guarantee at least one selected worker per entry
    beta = beta.at[0].set(1.0)
    b = jnp.asarray(rng.uniform(0.5, 2.0, size=D))
    return k_i, beta, b


def test_theorem1_reduces_to_lemma2_when_ideal():
    """All workers selected + no noise  =>  A = 1 - mu/L, B = 0 (Lemma 2)."""
    c = LearningConstants(L=2.0, mu=1.0, rho1=0.3, rho2=0.01, sigma2=0.0)
    U, D = 6, 11
    k_i = jnp.full((U,), 10.0)
    beta = jnp.ones((U, D))
    b = jnp.ones((D,))
    assert np.isclose(float(cv.A_t(beta, k_i, c)), 1 - c.mu / c.L)
    assert np.isclose(float(cv.B_t(beta, b, k_i, c)), 0.0)


def test_A_t_increases_when_fewer_workers():
    c = LearningConstants()
    k_i, beta, b = _setup()
    a_full = cv.A_t(jnp.ones_like(beta), k_i, c)
    a_part = cv.A_t(beta, k_i, c)
    assert float(a_part) >= float(a_full)


def test_B_t_decreases_with_power_scale():
    c = LearningConstants(sigma2=1e-2)
    k_i, beta, b = _setup()
    b_small = cv.B_t(beta, 0.5 * b, k_i, c)
    b_large = cv.B_t(beta, 2.0 * b, k_i, c)
    assert float(b_large) < float(b_small)


def test_gap_recursion_matches_manual_unroll():
    c = LearningConstants()
    T = 9
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.uniform(0.5, 0.9, T))
    bb = jnp.asarray(rng.uniform(0.0, 0.1, T))
    traj = cv.gap_recursion(a, bb, gap0=3.0)
    ref = 3.0
    for t in range(T):
        ref = float(bb[t]) + float(a[t]) * ref
        assert np.isclose(float(traj[t]), ref, rtol=1e-10)


def test_ideal_rate_geometric():
    c = LearningConstants(L=4.0, mu=1.0)
    assert np.isclose(float(cv.ideal_rate(3, 8.0, c)), (0.75) ** 3 * 8.0)


def test_remark1_sgd_equals_gd_when_kb_is_ki():
    """Theorem 3 with K_b = K_i (uniform) must equal Theorem 1."""
    c = LearningConstants(L=2.0, mu=0.7, rho1=0.2, rho2=0.005, sigma2=1e-3)
    U, D = 4, 6
    kb = 25.0
    k_i = jnp.full((U,), kb)
    rng = np.random.default_rng(2)
    beta = jnp.asarray(rng.integers(0, 2, (U, D)), jnp.float64).at[1].set(1.0)
    b = jnp.asarray(rng.uniform(0.5, 1.5, D))
    np.testing.assert_allclose(float(cv.A_t_sgd(beta, k_i, kb, c)),
                               float(cv.A_t(beta, k_i, c)), rtol=1e-9)
    np.testing.assert_allclose(float(cv.B_t_sgd(beta, b, k_i, kb, c)),
                               float(cv.B_t(beta, b, k_i, c)), rtol=1e-9)


def test_sgd_gap_terms_decrease_with_kb():
    """Remark 1: larger mini-batch K_b  =>  smaller A_t^SGD and B_t^SGD."""
    c = LearningConstants(L=2.0, mu=0.7, rho1=0.2, rho2=0.005, sigma2=1e-3)
    U, D = 5, 4
    k_i = jnp.full((U,), 40.0)
    beta = jnp.ones((U, D))
    b = jnp.ones((D,))
    a_small = float(cv.A_t_sgd(beta, k_i, 5.0, c))
    a_big = float(cv.A_t_sgd(beta, k_i, 30.0, c))
    assert a_big <= a_small + 1e-12
    bs = float(cv.B_t_sgd(beta, b, k_i, 5.0, c))
    bl = float(cv.B_t_sgd(beta, b, k_i, 30.0, c))
    assert bl <= bs + 1e-12


def test_proposition1_condition_makes_At_contractive():
    c0 = LearningConstants(L=2.0, mu=1.0, rho1=0.1, rho2=0.0, sigma2=0.0)
    U, D = 6, 8
    rng = np.random.default_rng(3)
    k_i = jnp.asarray(rng.integers(10, 30, U), jnp.float64)
    lim = float(cv.rho2_limit_gd(k_i, D, c0))
    c = LearningConstants(L=c0.L, mu=c0.mu, rho1=c0.rho1,
                          rho2=0.99 * lim, sigma2=0.0)
    # worst-case selection: a single worker (the smallest) per entry
    i_min = int(jnp.argmin(k_i))
    beta = jnp.zeros((U, D)).at[i_min].set(1.0)
    assert float(cv.A_t(beta, k_i, c)) < 1.0


def test_rho2_limit_sgd_positive_and_tighter_for_small_kb():
    c = LearningConstants(L=2.0, mu=1.0)
    U, K, D = 10, 400, 6
    lim_small = float(cv.rho2_limit_sgd(U, K, 4, D, c))
    lim_big = float(cv.rho2_limit_sgd(U, K, 40, D, c))
    assert lim_small > 0 and lim_big > 0
    assert lim_small <= lim_big  # bigger batches tolerate larger rho2


def test_nonconvex_bound_decays_in_T():
    c = LearningConstants(L=2.0, mu=1.0, rho1=0.1, rho2=1e-4)
    k_i = jnp.full((5,), 20.0)
    v_small = float(cv.nonconvex_stationarity_bound(0.5, 10, 4.0, k_i, 3, c))
    v_big = float(cv.nonconvex_stationarity_bound(0.5, 1000, 4.0, k_i, 3, c))
    assert v_big < v_small
