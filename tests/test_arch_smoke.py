"""Per-architecture smoke tests: reduced variant of each assigned family,
one forward/train step + one prefill+decode step on CPU, asserting output
shapes and absence of NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models.api import Model
from repro.models.config import ShapeConfig

SMOKE_TRAIN = ShapeConfig("smoke_train", seq_len=32, global_batch=2,
                          kind="train")
SMOKE_PREFILL = ShapeConfig("smoke_prefill", seq_len=32, global_batch=2,
                            kind="prefill")

ALL_ARCHS = sorted(registry.ARCHS)


def _finite(tree):
    leaves = jax.tree.leaves(tree)
    return all(bool(jnp.all(jnp.isfinite(l))) for l in leaves
               if jnp.issubdtype(l.dtype, jnp.floating))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch):
    cfg = registry.reduced(registry.get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = registry.make_batch(cfg, SMOKE_TRAIN)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda pp: model.loss(pp, b), has_aux=True)(p)
        new_p = jax.tree.map(lambda w, g: w - 1e-2 * g.astype(w.dtype),
                             p, grads)
        return loss, metrics, new_p

    loss, metrics, new_params = step(params, batch)
    assert np.isfinite(float(loss)), arch
    assert _finite(new_params), f"{arch}: non-finite params after step"
    # loss should move (gradients are non-trivial)
    loss2, _, _ = step(new_params, batch)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_and_decode(arch):
    cfg = registry.reduced(registry.get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = registry.make_batch(cfg, SMOKE_PREFILL)

    logits, caches = jax.jit(model.prefill)(params, batch)
    B = SMOKE_PREFILL.global_batch
    vpad = jax.tree.leaves(
        {"t": params["embed"]["tok"]})[0].shape[0]
    assert logits.shape == (B, vpad)
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    # one decode step against freshly initialized caches
    seq = 64
    dc = model.init_decode_caches(B, seq)
    tok = jnp.zeros((B, 1), jnp.int32)
    dlogits, dc2 = jax.jit(model.decode_step,
                           static_argnames=())(params, dc, tok, 5)
    assert dlogits.shape == (B, vpad)
    assert bool(jnp.all(jnp.isfinite(dlogits))), arch
    # caches actually updated
    changed = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), dc, dc2)
    assert any(jax.tree.leaves(changed)), f"{arch}: decode did not write cache"


def test_decode_matches_prefill_dense():
    """Greedy consistency: token-by-token decode reproduces the full-seq
    forward for a small dense model (qwen2 reduced)."""
    cfg = registry.reduced(registry.get_config("qwen2-0.5b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    B, T = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0,
                              cfg.vocab_size)
    full_logits, _, _ = __import__(
        "repro.models.transformer", fromlist=["forward_tokens"]
    ).forward_tokens(params, cfg, toks, mode="prefill", remat=False)

    caches = model.init_decode_caches(B, T)
    outs = []
    for t in range(T):
        lg, caches = model.decode_step(params, caches, toks[:, t:t + 1], t)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-4, atol=2e-4)


def test_decode_matches_prefill_rwkv():
    cfg = registry.reduced(registry.get_config("rwkv6-7b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    B, T = 2, 9
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, T), 0,
                              cfg.vocab_size)
    from repro.models.transformer import forward_tokens
    full_logits, _, _ = forward_tokens(params, cfg, toks, mode="prefill",
                                       remat=False)
    caches = model.init_decode_caches(B, 1)
    outs = []
    for t in range(T):
        lg, caches = model.decode_step(params, caches, toks[:, t:t + 1], t)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_prefill_hybrid():
    cfg = registry.reduced(registry.get_config("recurrentgemma-2b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    B, T = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, T), 0,
                              cfg.vocab_size)
    from repro.models.transformer import forward_tokens
    full_logits, _, _ = forward_tokens(params, cfg, toks, mode="prefill",
                                       remat=False)
    caches = model.init_decode_caches(B, T)
    outs = []
    for t in range(T):
        lg, caches = model.decode_step(params, caches, toks[:, t:t + 1], t)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)


def test_param_counts_match_scale():
    """Full configs report plausible analytic parameter counts."""
    expected_range = {
        "qwen2-0.5b": (0.3e9, 0.8e9),
        "codeqwen1.5-7b": (5e9, 9e9),
        "rwkv6-7b": (5e9, 9e9),
        "gemma2-27b": (22e9, 32e9),
        "qwen1.5-110b": (95e9, 125e9),
        "arctic-480b": (380e9, 520e9),
        "qwen3-moe-235b-a22b": (200e9, 270e9),
        "recurrentgemma-2b": (2e9, 4e9),
        "internvl2-26b": (17e9, 26e9),   # LM side of the 26b VLM
        "whisper-base": (0.04e9, 0.11e9),
    }
    for arch, (lo, hi) in expected_range.items():
        n = registry.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
