"""OTA aggregation (eqs. 8-9) + power policy (6-7) behaviour tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import aggregation as agg
from repro.core import power as power_lib

jax.config.update("jax_enable_x64", True)


def _rand(seed, U=6, D=9):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(U, D)))
    h = jnp.asarray(rng.exponential(size=(U, D)) + 1e-2)
    k_i = jnp.asarray(rng.integers(5, 20, U), jnp.float64)
    return w, h, k_i


def test_noise_free_all_selected_equals_fedavg():
    """With beta=1, any common b, no noise and no clipping, (9) == (5)."""
    w, h, k_i = _rand(0)
    U, D = w.shape
    beta = jnp.ones((U, D))
    b = jnp.full((D,), 1.7)
    what, _ = agg.ota_aggregate(w, h, beta, b, k_i,
                                p_max=jnp.inf, noise=jnp.zeros(D), clip=False)
    np.testing.assert_allclose(np.asarray(what),
                               np.asarray(agg.fedavg(w, k_i)), rtol=1e-10)


def test_noise_free_subset_equals_weighted_subset_average():
    w, h, k_i = _rand(1)
    U, D = w.shape
    beta = jnp.zeros((U, D)).at[jnp.array([0, 2, 3])].set(1.0)
    b = jnp.full((D,), 0.9)
    what, _ = agg.ota_aggregate(w, h, beta, b, k_i, p_max=jnp.inf,
                                noise=jnp.zeros(D), clip=False)
    sel = np.array([0, 2, 3])
    ref = (np.asarray(k_i)[sel, None] * np.asarray(w)[sel]).sum(0) \
        / np.asarray(k_i)[sel].sum()
    np.testing.assert_allclose(np.asarray(what), ref, rtol=1e-10)


def test_clipping_never_violates_power_budget():
    w, h, k_i = _rand(2)
    U, D = w.shape
    beta = jnp.ones((U, D))
    b = jnp.full((D,), 50.0)  # aggressive scaling to force clipping
    p_max = jnp.asarray(np.random.default_rng(3).uniform(0.1, 2.0, U))
    viol = power_lib.power_violation(w, beta, k_i, b, h, p_max)
    assert float(viol) <= 1e-9


def test_tx_signal_matches_policy_when_within_budget():
    """Below the power limit the clipped signal equals p*w exactly (eq. 6)."""
    w, h, k_i = _rand(4)
    U, D = w.shape
    beta = jnp.ones((U, D))
    b = jnp.full((D,), 1e-3)  # tiny b => never clipped
    tx = power_lib.tx_signal(w, beta, k_i, b, h, p_max=1e6)
    ref = power_lib.tx_signal_unclipped(w, beta, k_i, b, h)
    np.testing.assert_allclose(np.asarray(tx), np.asarray(ref), rtol=1e-9)


def test_unselected_entries_flagged_zero():
    w, h, k_i = _rand(5)
    U, D = w.shape
    beta = jnp.zeros((U, D))
    what, _ = agg.ota_aggregate(w, h, beta, jnp.ones(D), k_i,
                                p_max=1.0, noise=jnp.zeros(D))
    assert np.all(np.asarray(what) == 0.0)


def test_noise_enters_inversely_scaled_by_denominator():
    """w_hat - fedavg == z / (sum K_i beta b): doubling b halves noise error."""
    w, h, k_i = _rand(6)
    U, D = w.shape
    beta = jnp.ones((U, D))
    z = jnp.asarray(np.random.default_rng(7).normal(size=D))
    e1, _ = agg.ota_aggregate(w, h, beta, jnp.full((D,), 1.0), k_i,
                              p_max=jnp.inf, noise=z, clip=False)
    e2, _ = agg.ota_aggregate(w, h, beta, jnp.full((D,), 2.0), k_i,
                              p_max=jnp.inf, noise=z, clip=False)
    fa = agg.fedavg(w, k_i)
    np.testing.assert_allclose(np.asarray(e1 - fa),
                               2.0 * np.asarray(e2 - fa), rtol=1e-9)


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 8), st.integers(1, 16), st.integers(0, 10_000))
def test_property_ota_linear_in_workers(U, D, seed):
    """Superposition is linear: aggregating w and w' then summing equals
    aggregating (w + w') — with common (beta, b, h, no clip, no noise)."""
    rng = np.random.default_rng(seed)
    w1 = jnp.asarray(rng.normal(size=(U, D)))
    w2 = jnp.asarray(rng.normal(size=(U, D)))
    h = jnp.asarray(rng.exponential(size=(U, D)) + 1e-2)
    k_i = jnp.asarray(rng.integers(1, 9, U), jnp.float64)
    beta = jnp.ones((U, D))
    b = jnp.full((D,), float(rng.uniform(0.5, 2.0)))
    z = jnp.zeros(D)
    a1, _ = agg.ota_aggregate(w1, h, beta, b, k_i, jnp.inf, z, clip=False)
    a2, _ = agg.ota_aggregate(w2, h, beta, b, k_i, jnp.inf, z, clip=False)
    a12, _ = agg.ota_aggregate(w1 + w2, h, beta, b, k_i, jnp.inf, z,
                               clip=False)
    np.testing.assert_allclose(np.asarray(a1 + a2), np.asarray(a12),
                               rtol=1e-8, atol=1e-10)
