"""Single-compile sweep cohorts: ragged worker padding + traced scalars.

Four families of checks (ISSUE 4):

  * partition semantics — a U x eps x sigma2 grid that previously split
    into one cohort per (U, eps) combination is ONE cohort per backend;
  * exactness tiers, stated precisely:
      - same-shape cohorts with traced eps / rho / sigma2 operands are
        BIT-EXACT against sequential ``FLTrainer`` runs (the operand
        arithmetic is pinned to the array dtype on both paths);
      - the ragged MASKING itself is bit-exact at op level: an eagerly
        evaluated padded+masked round reproduces the unpadded round
        bit-for-bit (restriction-stable worker keys + exact-zero padded
        contributions);
      - whole ragged cohorts match sequential runs to float32
        reassociation tolerance (~1e-6 relative): XLA regroups SIMD
        reductions when the worker-axis extent changes, which is the one
        thing zero-padding cannot hold fixed across compiled programs;
  * ragged edge shapes — a U=1 cohort member and a cell whose mask pads
    out most of the cohort's workers;
  * guard rails — mixed None/number scalar axes and instance channels in
    ragged cohorts fail loudly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import channel as chan
from repro.core.channel import (ChannelConfig, ExpIID, GaussMarkovFading,
                                ImperfectCSI, make_channel)
from repro.core.convergence import LearningConstants
from repro.core.objectives import Case
from repro.data.tasks import build_task_data
from repro.fl.engine import build_ota_stage
from repro.fl.trainer import FLConfig, FLTrainer, pad_workers
from repro.sweep import SweepSpec, run_spec
from repro.sweep.grid import cells, cohorts, result_by

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(autouse=True)
def _float32_mode():
    """The engine runs f32 in production; other test modules flip the
    global x64 switch at import, which changes the RNG streams and the
    traced-vs-concrete scalar promotion these exactness tests pin."""
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", False)
    yield
    jax.config.update("jax_enable_x64", old)


K_BAR, ROUNDS = 10, 6
# float32 reassociation tolerance for cross-shape comparisons (see module
# docstring); same-shape comparisons assert exact equality instead
RAGGED_RTOL = 2e-6


def _sequential(cell, *, eval_data=None):
    """The standalone FLTrainer run a sweep cell must reproduce."""
    u = cell["U"]
    task, workers, test = build_task_data(
        cell["task"], U=u, k_bar=cell["k_bar"], data_seed=cell["data_seed"])
    model = cell["channel"]
    kw = {k: cell[k] for k in ("eps", "rho") if cell[k] is not None}
    chanc = ChannelConfig(sigma2=cell["sigma2"], p_max=cell["p_max"])
    if kw:
        model = chan.resolve_model(model, u, chanc, **kw)
    case = cell["case"] if isinstance(cell["case"], Case) \
        else Case(cell["case"])
    cfg = FLConfig(rounds=cell["rounds"], lr=cell["lr"],
                   policy=cell["policy"], case=case, k_b=cell["k_b"],
                   channel=chanc, channel_model=model,
                   constants=LearningConstants(sigma2=cell["sigma2"]),
                   backend=cell["backend"], scan=True)
    h = FLTrainer(task, workers, cfg).run(
        key=jax.random.PRNGKey(cell["seed"]),
        eval_data=test if eval_data is None else eval_data)
    return h, np.asarray(ravel_pytree(h["params"])[0])


# ----------------------------------------------------- partition semantics

def test_u_eps_sigma2_grid_is_one_cohort_per_backend():
    """The ISSUE-4 acceptance grid: 12 cells that the pre-ragged engine
    split into 6 cohorts (U x eps) compile ONCE, on both backends, and
    every cell matches its sequential twin within reassociation
    tolerance."""
    for backend in ("jnp", "pallas"):
        spec = SweepSpec(
            axes={"U": (3, 5, 8), "eps": (0.0, 0.1),
                  "sigma2": (1e-4, 1e-2)},
            base={"k_bar": K_BAR, "rounds": 5, "channel": "exp_iid_csi",
                  "backend": backend})
        cl = cells(spec)
        assert len(cl) == 12
        assert len(cohorts(cl, legacy=True)) == 6     # the old plan
        cos = cohorts(cl)
        assert len(cos) == 1 and cos[0].ragged        # the new plan
        results = run_spec(spec)
        for r in results:
            h, flat = _sequential(r["cell"])
            np.testing.assert_allclose(r["flat"], flat, rtol=RAGGED_RTOL,
                                       atol=1e-7)
            np.testing.assert_allclose(
                np.asarray(r["history"]["mse"]), np.asarray(h["mse"]),
                rtol=RAGGED_RTOL, atol=1e-8)


# ------------------------------------------------- exactness: same shapes

def test_traced_eps_bitexact_vs_static_path():
    """eps varies inside one cohort (traced operand, jnp.where rewrite of
    the eps == 0 Python branch) — every cell, INCLUDING eps = 0, is
    bit-exact against the old static-``ImperfectCSI`` sequential path."""
    spec = SweepSpec(axes={"eps": (0.0, 0.1, 0.3)},
                     base={"U": 6, "k_bar": K_BAR, "rounds": ROUNDS,
                           "channel": "exp_iid_csi", "backend": "jnp"})
    assert len(cohorts(cells(spec))) == 1
    for r in run_spec(spec):
        h, flat = _sequential(r["cell"])        # static float eps
        np.testing.assert_array_equal(r["flat"], flat)
        np.testing.assert_array_equal(np.asarray(r["history"]["mse"]),
                                      np.asarray(h["mse"]))


def test_traced_eps0_estimator_equals_static():
    """Estimator-level: a traced zero eps selects the perfect-CSI gains
    bit-for-bit (the jnp.where keeps h exactly)."""
    gains = jnp.asarray(np.random.default_rng(0).exponential(size=16),
                        jnp.float32)
    key = jax.random.PRNGKey(1)
    static = ImperfectCSI(ExpIID(u=16), eps=0.0).estimate(gains, key)

    def traced(eps):
        return ImperfectCSI(ExpIID(u=16), eps=eps).estimate(gains, key)

    out = jax.jit(traced)(jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(static))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gains))


def test_traced_rho_bitexact():
    """rho varies inside one Gauss-Markov cohort; previously each rho was
    its own compiled cohort (static dataclass field)."""
    spec = SweepSpec(axes={"rho": (0.5, 0.9)},
                     base={"U": 6, "k_bar": K_BAR, "rounds": ROUNDS,
                           "channel": "gauss_markov", "backend": "jnp"})
    cl = cells(spec)
    assert len(cohorts(cl)) == 1
    assert len(cohorts(cl, legacy=True)) == 2
    for r in run_spec(spec):
        h, flat = _sequential(r["cell"])        # static float rho
        np.testing.assert_array_equal(r["flat"], flat)


def test_traced_sigma2_pallas_single_cohort():
    """sigma2 swept through the PALLAS backend: the kernels take L /
    sigma2 as SMEM scalar operands now, so the cohort no longer splits
    (nor falls back) — and matches sequential pallas runs."""
    spec = SweepSpec(axes={"sigma2": (1e-4, 1e-2)},
                     base={"U": 5, "k_bar": K_BAR, "rounds": 4,
                           "backend": "pallas"})
    assert len(cohorts(cells(spec))) == 1
    for r in run_spec(spec):
        h, flat = _sequential(r["cell"])
        np.testing.assert_array_equal(r["flat"], flat)


# ------------------------------------------------ exactness: masking level

def test_padded_masked_round_op_exact():
    """The load-bearing masking statement, free of XLA fusion effects:
    one eagerly evaluated OTA round on a (U + pad)-worker fleet with a
    worker mask reproduces the U-worker round BIT-exactly, because (a)
    per-worker randomness is restriction-stable and (b) padded workers
    contribute exact zeros to every reduction."""
    U, pad = 5, 3
    task, workers, _ = build_task_data("linreg", U=U, k_bar=K_BAR,
                                       data_seed=0)
    _, _, _, k_i = pad_workers(workers)
    D = 2
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(U, D)), jnp.float32)
    w_prev = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    cfg = FLConfig(policy="inflota", channel=ChannelConfig(),
                   constants=LearningConstants(), backend="jnp")

    def one_round(u_total, Wm, k, wmask):
        stage = build_ota_stage(cfg, k, D, wmask=wmask)
        key = jax.random.PRNGKey(7)
        return stage(Wm, w_prev, w_prev, jnp.zeros(()), (),
                     key, jax.random.fold_in(key, 1), jnp.int32(0))

    plain = one_round(U, W, k_i, None)
    padded = one_round(
        U + pad,
        jnp.concatenate([W, jnp.tile(w_prev[None], (pad, 1))]),
        jnp.concatenate([k_i, jnp.zeros((pad,))]),
        jnp.asarray([1.0] * U + [0.0] * pad, jnp.float32))
    names = ("flat", "delta", "carry", "sel", "b", "a_t", "b_t",
             "eta", "snr")
    assert len(plain) == len(padded) == len(names)
    for a, b, name in zip(plain, padded, names):
        if name == "carry":
            continue
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


# ------------------------------------------------------- ragged edge cases

def test_u1_cohort_member():
    """A single-worker cell rides a ragged cohort: the Theorem-4 search
    degenerates to one candidate and the padded workers stay silent."""
    spec = SweepSpec(axes={"U": (1, 4)},
                     base={"k_bar": K_BAR, "rounds": ROUNDS,
                           "backend": "jnp"})
    assert len(cohorts(cells(spec))) == 1
    results = run_spec(spec)
    r1 = result_by(results, U=1)
    assert np.all(np.asarray(r1["history"]["selected"]) <= 1.0 + 1e-6)
    for r in results:
        h, flat = _sequential(r["cell"])
        np.testing.assert_allclose(r["flat"], flat, rtol=RAGGED_RTOL,
                                   atol=1e-7)
        np.testing.assert_allclose(
            np.asarray(r["history"]["selected"]),
            np.asarray(h["selected"]), atol=1e-6)


def test_mostly_padded_cell():
    """A U=2 cell inside a U_max=16 cohort: 87% of its worker rows are
    padding, and none of them may select, transmit, or shift stats."""
    spec = SweepSpec(axes={"U": (2, 16)},
                     base={"k_bar": K_BAR, "rounds": ROUNDS,
                           "policy": "random", "backend": "jnp"})
    assert len(cohorts(cells(spec))) == 1
    results = run_spec(spec)
    small = result_by(results, U=2)
    assert np.all(np.asarray(small["history"]["selected"]) <= 2.0 + 1e-6)
    for r in results:
        h, flat = _sequential(r["cell"])
        np.testing.assert_allclose(r["flat"], flat, rtol=RAGGED_RTOL,
                                   atol=1e-7)
        np.testing.assert_allclose(
            np.asarray(r["history"]["selected"]),
            np.asarray(h["selected"]), atol=1e-6)


def test_minibatch_cells_ride_ragged_cohorts():
    """SGD / k_b cells ragged-merge now (ISSUE 6): the per-sample
    ``fold_in`` minibatch sampler draws each sample's inclusion from a
    key that ignores the padded worker- and sample-axis extents, so a
    cell's batch picks are identical inside any cohort.  Every cell must
    match its standalone trainer run."""
    spec = SweepSpec(axes={"U": (4, 6)},
                     base={"k_bar": 12, "rounds": ROUNDS, "k_b": 3,
                           "case": "sgd", "backend": "jnp"})
    cos = cohorts(cells(spec))
    assert len(cos) == 1 and cos[0].ragged
    results = run_spec(spec)
    for r in results:
        h, flat = _sequential(r["cell"])
        np.testing.assert_allclose(r["flat"], flat, rtol=RAGGED_RTOL,
                                   atol=1e-7)
        np.testing.assert_allclose(
            np.asarray(r["history"]["selected"]),
            np.asarray(h["selected"]), atol=1e-6)


def test_ragged_eval_uses_each_cells_test_split():
    """Per-cell test splits stack into a per-experiment eval operand; the
    mse history of every cell must match its standalone run, which
    evaluates against that cell's own split."""
    spec = SweepSpec(axes={"k_bar": (8, 20)},
                     base={"U": 5, "rounds": ROUNDS, "backend": "jnp"})
    assert len(cohorts(cells(spec))) == 1
    for r in run_spec(spec):
        h, _ = _sequential(r["cell"])
        np.testing.assert_allclose(
            np.asarray(r["history"]["mse"]), np.asarray(h["mse"]),
            rtol=RAGGED_RTOL, atol=1e-8)


# ------------------------------------------------------------- guard rails

def test_mixed_none_and_number_scalar_axis_rejected():
    spec = SweepSpec(axes={"eps": (None, 0.1)},
                     base={"U": 4, "k_bar": K_BAR, "rounds": 2,
                           "channel": "exp_iid_csi"})
    with pytest.raises(ValueError, match="mixes None"):
        run_spec(spec)


def test_eps_requires_compatible_channel():
    spec = SweepSpec(axes={"eps": (0.0, 0.1)},
                     base={"U": 4, "k_bar": K_BAR, "rounds": 2})
    with pytest.raises(ValueError, match="registry channel name"):
        run_spec(spec)


def test_instance_channel_cannot_span_ragged_u():
    spec = SweepSpec(axes={"U": (4, 6)},
                     base={"k_bar": K_BAR, "rounds": 2,
                           "channel": GaussMarkovFading(u=6)})
    # the instance is a static field, so each U still forms its own
    # cohort — but a hand-built ragged cohort must refuse it
    from repro.sweep.grid import Cohort, run_cohort
    cl = cells(spec)
    fake = Cohort(static={k: v for k, v in cl[0].items()
                          if k not in ("U", "seed")},
                  cells=cl, indices=list(range(len(cl))))
    assert fake.ragged
    with pytest.raises(ValueError, match="registry channel name or None"):
        run_cohort(fake, do_eval=False)


def test_ragged_exact_capability():
    assert chan.ragged_exact(None)
    assert chan.ragged_exact("exp_iid_csi")
    assert not chan.ragged_exact("pathloss")
    assert not chan.ragged_exact(
        ImperfectCSI(make_channel("pathloss", 4), eps=0.1))
